//! `nimrod-lint` — determinism & dirty-discipline static analysis for the
//! Nimrod/G broker codebase.
//!
//! The build is offline (no `syn`, no `clippy-utils`), so this is a
//! hand-rolled line/token scanner: source text is preprocessed into per-line
//! records with string literals and comments stripped out of the code channel
//! (comments are kept in a separate channel so `lint:allow` markers survive),
//! `#[cfg(test)]` modules are tracked by brace depth, and each rule then runs
//! over the cleaned token stream.
//!
//! ## Rules
//!
//! | ID           | What it catches                                                     |
//! |--------------|---------------------------------------------------------------------|
//! | ND-HASH      | `HashMap`/`HashSet` in tick-path modules (unordered iteration)      |
//! | ND-CLOCK     | `Instant::now`/`SystemTime`/OS entropy in sim paths                  |
//! | ND-FLOAT     | raw `.partial_cmp(` comparators outside `scheduler::index`          |
//! | DIRTY-PAIR   | a fn in `sim/world.rs` that marks views dirty but never re-keys     |
//! | PANIC-BUDGET | `.unwrap()`/`.expect()` in non-test library code                    |
//! | PAR-SHARED   | a `// lint:par-section` fn touching shared world state              |
//! | ALLOW-REASON | a `lint:allow` marker with no reason or an unknown rule ID          |
//!
//! ## Par-section markers
//!
//! Functions that run in the parallel per-tenant phase of the batched world
//! tick carry a `// lint:par-section` marker on the `fn` line (or in the
//! contiguous comment/attribute block directly above it). PAR-SHARED then
//! forbids shared-world-state access anywhere in their extent: no
//! `mark_view_all`, no `total_in_flight` mutation plumbing, no world-RNG
//! use — shared state is read through the phase-1 snapshot and mutated only
//! by the phase-3 merge barrier.
//!
//! ## Allow markers
//!
//! A diagnostic is suppressed by `// lint:allow(<RULE-ID>): <reason>` on the
//! same line, or anywhere in the contiguous block of comment/attribute-only
//! lines directly above it. The reason is mandatory: a bare
//! `// lint:allow(ND-CLOCK)` is itself an ALLOW-REASON violation and does not
//! suppress anything.

use std::fmt;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

pub mod fixtures;

/// Module directories whose contents run on (or feed) the deterministic tick
/// path. `types.rs` carries the IDs and enums those modules key state by, so
/// it is scoped in as well.
pub const TICK_PATH_DIRS: [&str; 5] = ["sim", "scheduler", "economy", "grid", "engine"];

// ---------------------------------------------------------------------------
// Rules & diagnostics
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rule {
    NdHash,
    NdClock,
    NdFloat,
    DirtyPair,
    PanicBudget,
    ParShared,
    AllowHygiene,
}

impl Rule {
    pub const ALL: [Rule; 7] = [
        Rule::NdHash,
        Rule::NdClock,
        Rule::NdFloat,
        Rule::DirtyPair,
        Rule::PanicBudget,
        Rule::ParShared,
        Rule::AllowHygiene,
    ];

    pub fn id(self) -> &'static str {
        match self {
            Rule::NdHash => "ND-HASH",
            Rule::NdClock => "ND-CLOCK",
            Rule::NdFloat => "ND-FLOAT",
            Rule::DirtyPair => "DIRTY-PAIR",
            Rule::PanicBudget => "PANIC-BUDGET",
            Rule::ParShared => "PAR-SHARED",
            Rule::AllowHygiene => "ALLOW-REASON",
        }
    }

    pub fn from_id(id: &str) -> Option<Rule> {
        Rule::ALL.iter().copied().find(|r| r.id() == id)
    }

    pub fn summary(self) -> &'static str {
        match self {
            Rule::NdHash => {
                "no HashMap/HashSet in tick-path modules (unordered iteration breaks replay)"
            }
            Rule::NdClock => {
                "no Instant::now/SystemTime/OS entropy in sim paths (time via simtime, rng via util::rng)"
            }
            Rule::NdFloat => {
                "no raw .partial_cmp comparators outside scheduler::index (use TotalF64/total_cmp)"
            }
            Rule::DirtyPair => {
                "a fn in sim/world.rs that marks views dirty must also re-key the CandidateIndex"
            }
            Rule::PanicBudget => "unwrap()/expect() in non-test library code must be allow-listed",
            Rule::ParShared => {
                "a lint:par-section fn must not touch shared world state (snapshot reads, merge-barrier writes only)"
            }
            Rule::AllowHygiene => "every lint:allow must name a known rule and carry a reason",
        }
    }
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diagnostic {
    pub rule: Rule,
    pub file: String,
    pub line: usize,
    pub message: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}: [{}] {}",
            self.file,
            self.line,
            self.rule.id(),
            self.message
        )
    }
}

// ---------------------------------------------------------------------------
// Preprocessing: split source into per-line code/comment channels
// ---------------------------------------------------------------------------

/// One source line after preprocessing. `code` has string/char literal
/// contents and comments blanked out; `comment` holds the comment text so
/// allow markers can be parsed without tripping the token rules.
#[derive(Debug, Default, Clone)]
struct SrcLine {
    code: String,
    comment: String,
    /// Line contributes no code: blank, comment-only, or attribute-only.
    annotation_only: bool,
    /// Line sits inside a `#[cfg(test)] mod … { … }` block.
    in_test: bool,
}

fn is_ident_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

fn preprocess(text: &str) -> Vec<SrcLine> {
    #[derive(PartialEq, Clone, Copy)]
    enum St {
        Code,
        Str,
        LineComment,
        BlockComment,
    }

    let chars: Vec<char> = text.chars().collect();
    let mut lines: Vec<SrcLine> = Vec::new();
    let mut code = String::new();
    let mut comment = String::new();
    let mut st = St::Code;
    let mut block_depth: u32 = 0;
    let mut i = 0usize;

    let flush = |lines: &mut Vec<SrcLine>, code: &mut String, comment: &mut String| {
        let trimmed = code.trim();
        let annotation_only = trimmed.is_empty()
            || trimmed.starts_with("#[")
            || trimmed.starts_with("#![");
        lines.push(SrcLine {
            code: std::mem::take(code),
            comment: std::mem::take(comment),
            annotation_only,
            in_test: false,
        });
    };

    while i < chars.len() {
        let c = chars[i];
        let next = chars.get(i + 1).copied();
        if c == '\n' {
            if st == St::LineComment {
                st = St::Code;
            }
            flush(&mut lines, &mut code, &mut comment);
            i += 1;
            continue;
        }
        match st {
            St::Code => {
                if c == '/' && next == Some('/') {
                    st = St::LineComment;
                    i += 2;
                } else if c == '/' && next == Some('*') {
                    st = St::BlockComment;
                    block_depth = 1;
                    i += 2;
                } else if c == '"' {
                    // String literal: keep the quotes, drop the contents.
                    code.push(' ');
                    st = St::Str;
                    i += 1;
                } else if c == '\'' {
                    // Char literal vs lifetime. `'\…'` and `'x'` are
                    // literals; `'a` (no closing quote right after) is a
                    // lifetime and passes through.
                    if next == Some('\\') {
                        i += 2;
                        while i < chars.len() && chars[i] != '\'' && chars[i] != '\n' {
                            i += 1;
                        }
                        if i < chars.len() && chars[i] == '\'' {
                            i += 1;
                        }
                        code.push(' ');
                    } else if chars.get(i + 2).copied() == Some('\'') && next != Some('\'') {
                        code.push(' ');
                        i += 3;
                    } else {
                        code.push('\'');
                        i += 1;
                    }
                } else {
                    code.push(c);
                    i += 1;
                }
            }
            St::Str => {
                if c == '"' {
                    code.push(' ');
                    st = St::Code;
                    i += 1;
                } else if c == '\\' && next != Some('\n') {
                    // Skip the escaped char; `\<newline>` continuations fall
                    // through so line accounting stays exact.
                    i += 2;
                } else {
                    i += 1;
                }
            }
            St::LineComment => {
                comment.push(c);
                i += 1;
            }
            St::BlockComment => {
                if c == '*' && next == Some('/') {
                    block_depth -= 1;
                    if block_depth == 0 {
                        st = St::Code;
                    }
                    i += 2;
                } else if c == '/' && next == Some('*') {
                    block_depth += 1;
                    i += 2;
                } else {
                    comment.push(c);
                    i += 1;
                }
            }
        }
    }
    flush(&mut lines, &mut code, &mut comment);
    lines
}

/// Byte offsets where `tok` occurs in `code` as a standalone token. Ident
/// boundaries are only enforced on a token edge that is itself an ident char
/// (so `.unwrap(` is not found inside `.unwrap_or(`, while `x.partial_cmp(`
/// still matches the dotted token).
fn token_positions(code: &str, tok: &str) -> Vec<usize> {
    let mut out = Vec::new();
    if tok.is_empty() {
        return out;
    }
    let first_is_ident = tok.chars().next().is_some_and(is_ident_char);
    let last_is_ident = tok.chars().last().is_some_and(is_ident_char);
    let mut from = 0usize;
    while let Some(p) = code[from..].find(tok) {
        let at = from + p;
        let end = at + tok.len();
        let before_ok = !first_is_ident
            || at == 0
            || !code[..at].chars().next_back().is_some_and(is_ident_char);
        let after_ok = !last_is_ident
            || end >= code.len()
            || !code[end..].chars().next().is_some_and(is_ident_char);
        if before_ok && after_ok {
            out.push(at);
        }
        from = end;
    }
    out
}

/// True when `code` contains a *call* of `name` — the token followed by `(`
/// — that is not the `fn name(` definition itself.
fn has_call(code: &str, name: &str) -> bool {
    for at in token_positions(code, name) {
        let after = code[at + name.len()..].trim_start();
        if !after.starts_with('(') {
            continue;
        }
        let before = code[..at].trim_end();
        if let Some(pre) = before.strip_suffix("fn") {
            if pre.is_empty() || !pre.chars().next_back().is_some_and(is_ident_char) {
                continue; // `fn name(` — a definition, not a call
            }
        }
        return true;
    }
    false
}

/// Name of the function declared on this line, if any.
fn fn_decl_name(code: &str) -> Option<String> {
    for at in token_positions(code, "fn") {
        let rest = code[at + 2..].trim_start();
        let name: String = rest.chars().take_while(|&c| is_ident_char(c)).collect();
        if !name.is_empty() {
            return Some(name);
        }
    }
    None
}

/// Mark lines that live inside a `#[cfg(test)] mod … { … }` block. Any `mod`
/// item following a `#[cfg(test)]` attribute counts (the tree has both
/// `mod tests` and `pub(crate) mod testutil`).
fn mark_test_blocks(lines: &mut [SrcLine]) {
    let mut depth: i64 = 0;
    let mut test_depth: Option<i64> = None;
    let mut pending_cfg = false;
    let mut awaiting_mod_brace = false;

    for line in lines.iter_mut() {
        let code = line.code.clone();
        let trimmed = code.trim();
        if trimmed.contains("#[cfg(test)]") {
            pending_cfg = true;
        }
        let has_mod = !token_positions(&code, "mod").is_empty();
        let mut entered_at: Option<i64> = None;
        if (pending_cfg && has_mod) || awaiting_mod_brace {
            if code.contains('{') {
                entered_at = Some(depth);
                pending_cfg = false;
                awaiting_mod_brace = false;
            } else if has_mod {
                pending_cfg = false;
                awaiting_mod_brace = true;
            }
        } else if pending_cfg && !line.annotation_only && !trimmed.is_empty() && !has_mod {
            // The attribute landed on a non-mod item (e.g. `#[cfg(test)] fn`)
            // — that item is compiled out of release builds but is not a
            // module block we track; drop the pending flag.
            pending_cfg = false;
        }
        if test_depth.is_none() {
            test_depth = entered_at;
        }
        line.in_test = test_depth.is_some();
        let opens = code.matches('{').count() as i64;
        let closes = code.matches('}').count() as i64;
        depth += opens - closes;
        if let Some(td) = test_depth {
            if depth <= td {
                test_depth = None;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Allow markers
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
struct AllowMarker {
    raw_id: String,
    rule: Option<Rule>,
    has_reason: bool,
}

impl AllowMarker {
    fn valid_for(&self, rule: Rule) -> bool {
        self.rule == Some(rule) && self.has_reason
    }
}

const ALLOW_PREFIX: &str = "lint:allow(";

/// Parse every `lint:allow(RULE): reason` marker in one comment line.
fn parse_allow_markers(comment: &str) -> Vec<AllowMarker> {
    let mut out = Vec::new();
    let mut rest = comment;
    while let Some(p) = rest.find(ALLOW_PREFIX) {
        let after = &rest[p + ALLOW_PREFIX.len()..];
        let Some(close) = after.find(')') else {
            break;
        };
        let raw_id = after[..close].trim().to_string();
        let tail = &after[close + 1..];
        let has_reason = tail
            .strip_prefix(':')
            .is_some_and(|r| !r.trim().is_empty());
        out.push(AllowMarker {
            rule: Rule::from_id(&raw_id),
            raw_id,
            has_reason,
        });
        rest = tail;
    }
    out
}

/// Is a diagnostic for `rule` at 1-based `line` suppressed? Valid markers on
/// the same line, or in the contiguous run of annotation-only lines directly
/// above it, count. For function-anchored rules (DIRTY-PAIR) the anchor is
/// the `fn` line, so the same lookup applies.
fn is_allowed(lines: &[SrcLine], markers: &[Vec<AllowMarker>], line: usize, rule: Rule) -> bool {
    let idx = line - 1;
    let hit = |i: usize| markers[i].iter().any(|m| m.valid_for(rule));
    if hit(idx) {
        return true;
    }
    let mut j = idx;
    while j > 0 && lines[j - 1].annotation_only {
        j -= 1;
        if hit(j) {
            return true;
        }
    }
    false
}

// ---------------------------------------------------------------------------
// Scoping
// ---------------------------------------------------------------------------

fn norm_path(path: &str) -> String {
    path.replace('\\', "/")
}

fn is_tick_path(path: &str) -> bool {
    let p = norm_path(path);
    let parts: Vec<&str> = p.split('/').collect();
    if parts.last() == Some(&"types.rs") {
        return true;
    }
    parts.iter().any(|c| TICK_PATH_DIRS.contains(c))
}

fn is_float_exempt(path: &str) -> bool {
    // scheduler::index owns TotalF64 and the shared key helpers; a raw
    // partial_cmp there would be caught by its own equivalence tests.
    norm_path(path).ends_with("scheduler/index.rs")
}

fn is_world_file(path: &str) -> bool {
    norm_path(path).ends_with("sim/world.rs")
}

// ---------------------------------------------------------------------------
// Rule token tables
// ---------------------------------------------------------------------------

const HASH_TOKENS: [&str; 2] = ["HashMap", "HashSet"];

const CLOCK_TOKENS: [&str; 7] = [
    "Instant::now",
    "SystemTime",
    "UNIX_EPOCH",
    "thread_rng",
    "from_entropy",
    "getrandom",
    "RandomState",
];

const PANIC_TOKENS: [&str; 2] = [".unwrap(", ".expect("];

const FLOAT_TOKEN: &str = ".partial_cmp(";

/// Functions that push a view onto the dirty queue.
const DIRTY_TRIGGERS: [&str; 2] = ["mark_view", "mark_view_all"];

/// Calls that re-key the CandidateIndex (or drain the dirty queue into it).
/// `update_cols`/`update_cols_bulk` are the struct-of-arrays re-key paths
/// (per-entry and chunked-bulk) — key-identical to `update` by the shared
/// `_parts` helpers.
const REKEY_CALLS: [&str; 1] = ["refresh_dirty_views"];
const REKEY_SUBSTRINGS: [&str; 5] = [
    "index.update(",
    "index.update_cols(",
    "index.update_cols_bulk(",
    "index.rebuild_from(",
    "CandidateIndex::from_views(",
];

/// Marker naming a fn that runs in the parallel per-tenant tick phase.
const PAR_SECTION_MARKER: &str = "lint:par-section";

/// Calls forbidden inside a par-section extent: `mark_view_all` dirties
/// every tenant's view of a resource (cross-tenant write) and
/// `dec_total_in_flight` is the shared occupancy-table plumbing. Plain
/// `mark_view` stays legal — it is tenant-local.
const PAR_FORBIDDEN_CALLS: [&str; 2] = ["mark_view_all", "dec_total_in_flight"];

/// World-field accesses forbidden inside a par-section extent (matched with
/// ident-boundary ends, so `self.rng` does not hit a `self.rngs`): the
/// world RNG must be pre-forked into per-tenant sub-streams during the
/// snapshot phase, and the shared occupancy tables are read through the
/// frozen `WorldView`, never through `self`.
const PAR_FORBIDDEN_FIELDS: [&str; 3] =
    ["self.rng", "self.total_in_flight", "self.total_reserved"];

/// Calls that hand a closure to the persistent `WorkerPool` for execution
/// on the parallel lanes. The closure argument runs in phase 2 regardless
/// of where the call site sits, so the line (and any multi-line closure
/// body it opens) is held to the same par-section discipline as a fn
/// marked with `lint:par-section`. `scatter_streaming` additionally runs
/// its commit callback *while later shards are still in flight*, so its
/// whole call statement — commit closure included — is parallel-section
/// code too (the token must be listed separately: `_` is an ident char,
/// so a bare `scatter` token never matches `scatter_streaming(`).
const PAR_POOL_CALLS: [&str; 2] = ["scatter", "scatter_streaming"];

// ---------------------------------------------------------------------------
// Linting
// ---------------------------------------------------------------------------

/// Lint one source file. `path` drives rule scoping (tick-path detection,
/// the `sim/world.rs` DIRTY-PAIR scope) and is what appears in diagnostics —
/// fixture tests pass pseudo-paths like `"sim/state.rs"`.
pub fn lint_source(path: &str, text: &str) -> Vec<Diagnostic> {
    lint_file(path, path, text)
}

fn lint_file(scope_path: &str, display_path: &str, text: &str) -> Vec<Diagnostic> {
    let mut lines = preprocess(text);
    mark_test_blocks(&mut lines);
    let markers: Vec<Vec<AllowMarker>> = lines
        .iter()
        .map(|l| parse_allow_markers(&l.comment))
        .collect();

    let mut diags: Vec<Diagnostic> = Vec::new();

    // ALLOW-REASON: hygiene of the escape hatch itself. Never suppressible.
    for (idx, ms) in markers.iter().enumerate() {
        for m in ms {
            if m.rule.is_none() {
                diags.push(Diagnostic {
                    rule: Rule::AllowHygiene,
                    file: display_path.to_string(),
                    line: idx + 1,
                    message: format!(
                        "lint:allow names unknown rule `{}` (known: {})",
                        m.raw_id,
                        Rule::ALL.map(|r| r.id()).join(", ")
                    ),
                });
            } else if !m.has_reason {
                diags.push(Diagnostic {
                    rule: Rule::AllowHygiene,
                    file: display_path.to_string(),
                    line: idx + 1,
                    message: format!(
                        "lint:allow({}) has no reason — write `// lint:allow({}): <why>`",
                        m.raw_id, m.raw_id
                    ),
                });
            }
        }
    }

    let tick = is_tick_path(scope_path);
    let float_exempt = is_float_exempt(scope_path);

    let push = |diags: &mut Vec<Diagnostic>, rule: Rule, line: usize, message: String| {
        if !is_allowed(&lines, &markers, line, rule) {
            diags.push(Diagnostic {
                rule,
                file: display_path.to_string(),
                line,
                message,
            });
        }
    };

    for (idx, line) in lines.iter().enumerate() {
        let ln = idx + 1;
        let code = &line.code;
        if tick {
            // ND-HASH applies to test code too: a test that iterates a
            // HashMap can go flaky just as easily as the tick path.
            for tok in HASH_TOKENS {
                for _ in token_positions(code, tok) {
                    push(
                        &mut diags,
                        Rule::NdHash,
                        ln,
                        format!("`{tok}` in tick-path module — use BTreeMap/BTreeSet (ordered iteration) or allow with a reason"),
                    );
                }
            }
            if !line.in_test {
                for tok in CLOCK_TOKENS {
                    for _ in token_positions(code, tok) {
                        push(
                            &mut diags,
                            Rule::NdClock,
                            ln,
                            format!("`{tok}` in sim path — virtual time comes from simtime, randomness from util::rng"),
                        );
                    }
                }
            }
        }
        if !float_exempt {
            for _ in token_positions(code, FLOAT_TOKEN) {
                push(
                    &mut diags,
                    Rule::NdFloat,
                    ln,
                    "raw `.partial_cmp(` — use f64::total_cmp or scheduler::index::TotalF64 for a total order".to_string(),
                );
            }
        }
        if !line.in_test {
            for tok in PANIC_TOKENS {
                for _ in token_positions(code, tok) {
                    push(
                        &mut diags,
                        Rule::PanicBudget,
                        ln,
                        format!("`{}` in non-test code — handle the None/Err or allow with a reason", &tok[1..tok.len() - 1]),
                    );
                }
            }
        }
    }

    if is_world_file(scope_path) {
        check_dirty_pair(&lines, &markers, display_path, &mut diags);
    }
    // PAR-SHARED is marker-driven, so it runs on every file: wherever a
    // `lint:par-section` fn lives, its extent is checked.
    check_par_shared(&lines, &markers, display_path, &mut diags);

    diags.sort_by(|a, b| {
        (a.line, a.rule, a.message.as_str()).cmp(&(b.line, b.rule, b.message.as_str()))
    });
    diags
}

/// DIRTY-PAIR: track function extents by brace depth; a non-test fn whose
/// body calls `mark_view`/`mark_view_all` must also re-key the index in the
/// same body (directly or by draining the dirty queue), or carry an allow on
/// its `fn` line naming where the re-key happens.
fn check_dirty_pair(
    lines: &[SrcLine],
    markers: &[Vec<AllowMarker>],
    display_path: &str,
    diags: &mut Vec<Diagnostic>,
) {
    struct Frame {
        name: String,
        line: usize,
        body_depth: i64,
        marks: bool,
        rekeys: bool,
    }

    let mut depth: i64 = 0;
    let mut paren: i64 = 0;
    let mut open: Vec<Frame> = Vec::new();
    let mut pending: Option<(String, usize)> = None;
    let mut finished: Vec<Frame> = Vec::new();

    for (idx, line) in lines.iter().enumerate() {
        let ln = idx + 1;
        let code = &line.code;

        let line_marks = DIRTY_TRIGGERS.iter().any(|t| has_call(code, t));
        let line_rekeys = REKEY_CALLS.iter().any(|t| has_call(code, t))
            || REKEY_SUBSTRINGS.iter().any(|s| code.contains(s));

        if !line.in_test {
            if let Some(name) = fn_decl_name(code) {
                pending = Some((name, ln));
            }
        }

        for c in code.chars() {
            match c {
                '(' => paren += 1,
                ')' => paren -= 1,
                ';' => {
                    // A `;` at paren depth 0 between `fn sig` and `{` is a
                    // bodyless declaration (trait method) — cancel it.
                    if paren == 0 {
                        pending = None;
                    }
                }
                '{' => {
                    if let Some((name, l)) = pending.take() {
                        open.push(Frame {
                            name,
                            line: l,
                            body_depth: depth,
                            marks: line_marks,
                            rekeys: line_rekeys,
                        });
                    }
                    depth += 1;
                }
                '}' => {
                    depth -= 1;
                    let closed = open
                        .last()
                        .is_some_and(|top| top.body_depth == depth);
                    if closed {
                        let mut f = open.pop().expect("frame checked above");
                        f.marks |= line_marks;
                        f.rekeys |= line_rekeys;
                        finished.push(f);
                    }
                }
                _ => {}
            }
        }

        if let Some(top) = open.last_mut() {
            top.marks |= line_marks;
            top.rekeys |= line_rekeys;
        }
    }
    // Unclosed frames at EOF (truncated input) are checked too.
    finished.append(&mut open);

    for f in finished {
        if f.marks && !f.rekeys && !is_allowed(lines, markers, f.line, Rule::DirtyPair) {
            diags.push(Diagnostic {
                rule: Rule::DirtyPair,
                file: display_path.to_string(),
                line: f.line,
                message: format!(
                    "`fn {}` marks views dirty but never re-keys the CandidateIndex — pair the mark with index.update/refresh_dirty_views or allow with a reason naming where the re-key happens",
                    f.name
                ),
            });
        }
    }
}

/// PAR-SHARED: a fn carrying the `lint:par-section` marker (on its `fn`
/// line or in the contiguous annotation-only block directly above it) runs
/// concurrently with other tenants' shards in phase 2 of the batched tick.
/// Anywhere in its extent — nested fns and closures included — shared
/// world state is off limits: the forbidden calls/field accesses must move
/// to the snapshot (phase 1) or merge-barrier (phase 3) code. Diagnostics
/// land on the offending line and are suppressible with
/// `lint:allow(PAR-SHARED): reason` there.
fn check_par_shared(
    lines: &[SrcLine],
    markers: &[Vec<AllowMarker>],
    display_path: &str,
    diags: &mut Vec<Diagnostic>,
) {
    let marker_at = |idx: usize| lines[idx].comment.contains(PAR_SECTION_MARKER);
    // Same lookup shape as `is_allowed`: the marker counts on the decl
    // line itself or in the contiguous run of annotation-only lines above.
    let decl_marked = |line: usize| {
        let idx = line - 1;
        if marker_at(idx) {
            return true;
        }
        let mut j = idx;
        while j > 0 && lines[j - 1].annotation_only {
            j -= 1;
            if marker_at(j) {
                return true;
            }
        }
        false
    };

    struct Frame {
        body_depth: i64,
        marked: bool,
    }

    let mut depth: i64 = 0;
    let mut paren: i64 = 0;
    let mut open: Vec<Frame> = Vec::new();
    let mut pending: Option<bool> = None;

    for (idx, line) in lines.iter().enumerate() {
        let ln = idx + 1;
        let code = &line.code;

        if !line.in_test && fn_decl_name(code).is_some() {
            pending = Some(decl_marked(ln));
        }

        // A `pool.scatter(...)` line ships its closure to the parallel
        // lanes: the line itself is in the parallel section, and if the
        // closure body opens a brace the frame it pushes is marked so
        // multi-line closures stay covered. A single-line call never
        // leaks a frame — its trailing `;` at paren depth 0 cancels the
        // pending mark just like a bodyless trait method.
        let pool_line =
            !line.in_test && PAR_POOL_CALLS.iter().any(|n| has_call(code, n));
        if pool_line && pending.is_none() {
            pending = Some(true);
        }

        // In the parallel section on this line? True when a marked frame is
        // already open, or becomes open mid-line (one-line fn bodies).
        let mut in_par = pool_line || open.iter().any(|f| f.marked);
        for c in code.chars() {
            match c {
                '(' => paren += 1,
                ')' => paren -= 1,
                ';' => {
                    // Bodyless declaration (trait method) — cancel it.
                    if paren == 0 {
                        pending = None;
                    }
                }
                '{' => {
                    if let Some(marked) = pending.take() {
                        open.push(Frame {
                            body_depth: depth,
                            marked,
                        });
                        in_par |= marked;
                    }
                    depth += 1;
                }
                '}' => {
                    depth -= 1;
                    if open.last().is_some_and(|top| top.body_depth == depth) {
                        open.pop();
                    }
                }
                _ => {}
            }
        }

        if !in_par || line.in_test {
            continue;
        }
        let mut hit = |tok: &str, what: &str| {
            if !is_allowed(lines, markers, ln, Rule::ParShared) {
                diags.push(Diagnostic {
                    rule: Rule::ParShared,
                    file: display_path.to_string(),
                    line: ln,
                    message: format!(
                        "`{tok}` {what} inside a lint:par-section fn — shared state is read through the snapshot (phase 1) and mutated by the merge barrier (phase 3)"
                    ),
                });
            }
        };
        for name in PAR_FORBIDDEN_CALLS {
            if has_call(code, name) {
                hit(name, "call");
            }
        }
        for field in PAR_FORBIDDEN_FIELDS {
            if !token_positions(code, field).is_empty() {
                hit(field, "access");
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Tree walking & reporting
// ---------------------------------------------------------------------------

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    let mut entries: Vec<_> = fs::read_dir(dir)?.collect::<Result<_, _>>()?;
    entries.sort_by_key(|e| e.file_name());
    for e in entries {
        let p = e.path();
        if p.is_dir() {
            collect_rs_files(&p, out)?;
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Lint every `.rs` file under `root` (sorted walk, deterministic output).
/// Returns the diagnostics plus the number of files scanned. Scoping uses
/// the path relative to `root`; diagnostics display the full path.
pub fn lint_tree(root: &Path) -> io::Result<(Vec<Diagnostic>, usize)> {
    let mut files = Vec::new();
    if root.is_dir() {
        collect_rs_files(root, &mut files)?;
    } else {
        files.push(root.to_path_buf());
    }
    let mut diags = Vec::new();
    for f in &files {
        let text = fs::read_to_string(f)?;
        let rel = f.strip_prefix(root).unwrap_or(f);
        let scope = norm_path(&rel.to_string_lossy());
        let display = norm_path(&f.to_string_lossy());
        diags.extend(lint_file(&scope, &display, &text));
    }
    Ok((diags, files.len()))
}

/// Human-readable report: per-rule counts, then every diagnostic.
pub fn format_report(diags: &[Diagnostic], files_scanned: usize) -> String {
    let mut out = String::new();
    out.push_str("nimrod-lint report\n");
    out.push_str(&format!(
        "files scanned: {files_scanned}; violations: {}\n\n",
        diags.len()
    ));
    for rule in Rule::ALL {
        let n = diags.iter().filter(|d| d.rule == rule).count();
        out.push_str(&format!("  {:<13} {:>4}  {}\n", rule.id(), n, rule.summary()));
    }
    if !diags.is_empty() {
        out.push('\n');
        for d in diags {
            out.push_str(&format!("{d}\n"));
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Tests (scanner internals; rule-level fixture tests live in
// rust/tests/lint_clean.rs so the root crate's plain `cargo test` runs them)
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strings_and_comments_are_stripped_from_code() {
        let src = "let s = \"HashMap in a string\"; // HashMap in a comment\n";
        let lines = preprocess(src);
        assert_eq!(lines.len(), 2);
        assert!(!lines[0].code.contains("HashMap"));
        assert!(lines[0].comment.contains("HashMap in a comment"));
    }

    #[test]
    fn multiline_strings_keep_line_numbers_exact() {
        let src = "let plan = \"parameter x float range from 1 to 2 step 1; \\\n    task main \\\n\";\nlet m = HashMap::new();\n";
        let lines = preprocess(src);
        assert_eq!(lines.len(), 5);
        assert!(lines[0].code.contains("let plan"));
        assert!(lines[1].code.is_empty());
        assert!(lines[3].code.contains("HashMap"));
    }

    #[test]
    fn block_comments_nest_and_span_lines() {
        let src = "a /* one /* two */ still */ b\n/* open\nInstant::now()\n*/ c\n";
        let lines = preprocess(src);
        assert!(lines[0].code.contains('a') && lines[0].code.contains('b'));
        assert!(lines[2].code.is_empty());
        assert!(lines[2].comment.contains("Instant::now"));
        assert!(lines[3].code.contains('c'));
    }

    #[test]
    fn char_literals_and_lifetimes_do_not_derail_the_scanner() {
        let src = "fn f<'a>(c: char) -> bool { c == '\"' || c == '\\'' || c == 'x' }\nlet m = HashMap::new();\n";
        let lines = preprocess(src);
        assert!(lines[1].code.contains("HashMap"));
    }

    #[test]
    fn token_boundaries_respect_identifiers() {
        assert_eq!(token_positions("x.unwrap_or(0)", ".unwrap(").len(), 0);
        assert_eq!(token_positions("x.unwrap()", ".unwrap(").len(), 1);
        assert_eq!(token_positions("MyHashMapLike::new()", "HashMap").len(), 0);
        assert_eq!(token_positions("HashMap::new()", "HashMap").len(), 1);
        assert_eq!(token_positions("a.partial_cmp(b)", ".partial_cmp(").len(), 1);
        assert_eq!(token_positions("fn partial_cmp(a: f64)", ".partial_cmp(").len(), 0);
    }

    #[test]
    fn fn_definitions_are_not_calls() {
        assert!(!has_call("fn mark_view(&mut self, rid: ResourceId) {", "mark_view"));
        assert!(has_call("self.mark_view(rid);", "mark_view"));
        assert!(has_call("tenant.mark_view(rid)", "mark_view"));
    }

    #[test]
    fn cfg_test_mods_are_marked_including_pub_crate_testutil() {
        let src = "fn live() { x.unwrap(); }\n#[cfg(test)]\npub(crate) mod testutil {\n    fn t() { y.unwrap(); }\n}\nfn live2() { z.expect(\"m\"); }\n";
        let mut lines = preprocess(src);
        mark_test_blocks(&mut lines);
        assert!(!lines[0].in_test);
        assert!(lines[2].in_test);
        assert!(lines[3].in_test);
        assert!(lines[4].in_test);
        assert!(!lines[5].in_test);
    }

    #[test]
    fn allow_markers_parse_reason_and_rule() {
        let ms = parse_allow_markers(" lint:allow(ND-CLOCK): alloc_ns is wall-clock telemetry");
        assert_eq!(ms.len(), 1);
        assert_eq!(ms[0].rule, Some(Rule::NdClock));
        assert!(ms[0].has_reason);
        let ms = parse_allow_markers(" lint:allow(ND-CLOCK)");
        assert!(!ms[0].has_reason);
        let ms = parse_allow_markers(" lint:allow(ND-TYPO): whatever");
        assert_eq!(ms[0].rule, None);
    }

    #[test]
    fn scoping_tick_path_and_exemptions() {
        assert!(is_tick_path("sim/world.rs"));
        assert!(is_tick_path("scheduler/index.rs"));
        assert!(is_tick_path("types.rs"));
        assert!(is_tick_path("grid/testbed.rs"));
        assert!(!is_tick_path("plan/mod.rs"));
        assert!(!is_tick_path("util/bench.rs"));
        assert!(is_float_exempt("scheduler/index.rs"));
        assert!(!is_float_exempt("scheduler/mod.rs"));
        assert!(is_world_file("sim/world.rs"));
        assert!(!is_world_file("sim/live.rs"));
    }

    #[test]
    fn report_counts_per_rule() {
        let diags = lint_source("sim/state.rs", fixtures::ND_HASH_FIRING);
        let report = format_report(&diags, 1);
        assert!(report.contains("ND-HASH"));
        assert!(report.contains("files scanned: 1"));
    }

    #[test]
    fn diagnostics_display_as_file_line_rule() {
        let d = Diagnostic {
            rule: Rule::NdClock,
            file: "sim/world.rs".to_string(),
            line: 7,
            message: "msg".to_string(),
        };
        assert_eq!(format!("{d}"), "sim/world.rs:7: [ND-CLOCK] msg");
    }

    #[test]
    fn rule_ids_round_trip() {
        for r in Rule::ALL {
            assert_eq!(Rule::from_id(r.id()), Some(r));
        }
        assert_eq!(Rule::from_id("ND-TYPO"), None);
    }

    #[test]
    fn sorted_output_is_deterministic() {
        let mut a = lint_source("sim/state.rs", fixtures::ND_HASH_FIRING);
        let b = lint_source("sim/state.rs", fixtures::ND_HASH_FIRING);
        assert_eq!(a, b);
        a.sort_by(|x, y| (x.line, x.rule).cmp(&(y.line, y.rule)));
        assert_eq!(a, b);
    }

    #[test]
    fn one_line_fn_bodies_are_still_tracked() {
        let src = "impl W { fn poke(&mut self) { self.mark_view(rid); } }\n";
        let diags = lint_source("sim/world.rs", src);
        assert!(diags.iter().any(|d| d.rule == Rule::DirtyPair && d.line == 1));
    }

    #[test]
    fn par_section_extent_ends_at_the_closing_brace() {
        // The marked fn's body fires; the fn after it is outside the
        // extent and may touch shared state freely.
        let src = "// lint:par-section\nfn shard_work(wv: &WorldView) {\n    self.rng.next_u64();\n}\nfn merge(world: &mut World) {\n    world.mark_view_all(rid);\n    self.rng.next_u64();\n}\n";
        let diags = lint_source("sim/shard.rs", src);
        let par: Vec<usize> = diags
            .iter()
            .filter(|d| d.rule == Rule::ParShared)
            .map(|d| d.line)
            .collect();
        assert_eq!(par, vec![3]);
    }

    #[test]
    fn par_section_marker_reaches_through_doc_blocks_and_nested_items() {
        // Marker above a doc block still marks the fn, and a nested
        // (unmarked) closure/fn inside the extent inherits the discipline.
        let src = "// lint:par-section\n/// Docs in between.\nfn shard_work(wv: &WorldView) {\n    let f = |x| {\n        self.total_in_flight[x] += 1;\n    };\n    fn helper() {\n        other.mark_view_all(rid);\n    }\n}\n";
        let diags = lint_source("sim/shard.rs", src);
        let par: Vec<usize> = diags
            .iter()
            .filter(|d| d.rule == Rule::ParShared)
            .map(|d| d.line)
            .collect();
        assert_eq!(par, vec![5, 8]);
    }

    #[test]
    fn one_line_par_section_bodies_are_checked() {
        let src = "// lint:par-section\nfn poke(wv: &W) { self.rng.gen(); }\n";
        let diags = lint_source("sim/shard.rs", src);
        assert!(diags.iter().any(|d| d.rule == Rule::ParShared && d.line == 2));
    }

    #[test]
    fn pool_scatter_line_is_in_the_parallel_section() {
        // A single-line scatter call ships its closure to the worker
        // lanes: forbidden accesses on that line fire without any
        // lint:par-section marker, and the trailing `;` keeps the
        // pending mark from leaking into the next block.
        let src = "fn tick(&mut self) {\n    pool.scatter(&mut shards, |s| self.rng.fill(s));\n    {\n        self.rng.next_u64();\n    }\n}\n";
        let diags = lint_source("sim/world.rs", src);
        let par: Vec<usize> = diags
            .iter()
            .filter(|d| d.rule == Rule::ParShared)
            .map(|d| d.line)
            .collect();
        assert_eq!(par, vec![2]);
    }

    #[test]
    fn pool_scatter_multiline_closure_body_is_covered() {
        let src = "fn tick(&mut self) {\n    pool.scatter(&mut shards, |shard| {\n        world.mark_view_all(rid);\n    });\n    self.rng.next_u64();\n}\n";
        let diags = lint_source("sim/world.rs", src);
        let par: Vec<usize> = diags
            .iter()
            .filter(|d| d.rule == Rule::ParShared)
            .map(|d| d.line)
            .collect();
        assert_eq!(par, vec![3]);
    }

    #[test]
    fn clean_pool_scatter_raises_nothing() {
        let src = "fn tick(&mut self) {\n    pool.scatter(&mut shards, |shard| tick_tenant_shard(&wv, shard));\n    self.pool_rounds += 1;\n}\n";
        let diags = lint_source("sim/world.rs", src);
        assert!(diags.iter().all(|d| d.rule != Rule::ParShared));
    }

    #[test]
    fn trait_method_declarations_do_not_open_frames() {
        let src = "trait T {\n    fn poke(&mut self, rid: ResourceId);\n}\nimpl T for W {\n    fn poke(&mut self, rid: ResourceId) {\n        self.mark_view(rid);\n        self.tenant.index.update(&self.tenant.views[0]);\n    }\n}\n";
        let diags = lint_source("sim/world.rs", src);
        assert!(diags.iter().all(|d| d.rule != Rule::DirtyPair));
    }
}
