// Fixture: a reasoned allow inside a streaming-commit callback suppresses
// PAR-SHARED (e.g. a commit-time debug audit that only reads the live
// occupancy table the committer itself owns during the merge).
fn on_tick_batch(&mut self) {
    pool.scatter_streaming(
        &mut shards,
        |shard| tick_tenant_shard(&wv, shard),
        |shard, _overlapped| {
            // lint:allow(PAR-SHARED): commit queue is the sole writer of the live table; read-only audit here
            debug_assert!(self.total_in_flight[shard.rid.0 as usize] <= cap);
            commit_shard(&mut ctx, shard);
        },
    );
}
