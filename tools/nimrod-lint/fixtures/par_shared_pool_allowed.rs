// Fixture: a reasoned allow inside a scatter closure suppresses
// PAR-SHARED (e.g. a read-only audit of the shared occupancy table in a
// debug-only consistency check run on the worker lanes).
fn on_tick_batch(&mut self) {
    pool.scatter(&mut shards, |shard| {
        // lint:allow(PAR-SHARED): read-only debug audit against the live table; never written from here
        debug_assert_eq!(wv.total_in_flight[i], self.total_in_flight[i]);
        shard.tenant.mark_view(rid);
    });
}
