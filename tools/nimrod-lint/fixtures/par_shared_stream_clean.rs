// Fixture: a streaming merge that routes every commit through a MergeCtx
// (disjoint mutable slices handed in from outside the call) is clean, and
// the discipline still ends with the call statement — the post-batch
// replay right after it may touch shared state freely.
fn on_tick_batch(&mut self) {
    pool.scatter_streaming(
        &mut shards,
        |shard| tick_tenant_shard(&wv, shard),
        |shard, overlapped| commit_shard(&mut ctx, shard, overlapped),
    );
    self.pool_rounds += 1;
    self.drain_merge_buffers();
    self.total_in_flight[0] += marks.len() as u32;
}
