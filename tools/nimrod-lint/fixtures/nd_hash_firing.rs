// Fixture: ND-HASH fires on unordered maps in tick-path modules.
use std::collections::HashMap;

pub fn occupancy_by_resource() -> HashMap<u32, u32> {
    let mut m = HashMap::new();
    m.insert(1, 2);
    m
}
