// Fixture: ND-CLOCK fires on wall-clock reads in sim paths.
pub fn tick_now_ns() -> u128 {
    let t0 = std::time::Instant::now();
    t0.elapsed().as_nanos()
}
