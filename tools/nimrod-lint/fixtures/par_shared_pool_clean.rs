// Fixture: a scatter that hands each shard to a tenant-local worker fn is
// clean, and the discipline ends with the call — the merge barrier right
// after it may touch shared state freely.
fn on_tick_batch(&mut self) {
    pool.scatter(&mut shards, |shard| tick_tenant_shard(&wv, shard));
    self.pool_rounds += 1;
    for (tid, actions) in deltas {
        self.total_in_flight[tid] += actions.len() as u32;
        let tie = self.rng.next_u64();
    }
}
