// Fixture: PAR-SHARED fires on a `scatter_streaming` whose *commit*
// callback touches shared world state. Streamed commits run while
// higher-numbered shards are still in flight, so the whole call
// statement — phase closure and commit closure alike — is parallel-
// section code; mutating the live tables or drawing from the world RNG
// there races the lanes exactly like doing it inside the phase closure.
fn on_tick_batch(&mut self) {
    pool.scatter_streaming(
        &mut shards,
        |shard| tick_tenant_shard(&wv, shard),
        |shard, _overlapped| {
            self.total_in_flight[shard.rid.0 as usize] += 1;
            shard.jitter = self.rng.next_f64();
        },
    );
}
