// Fixture: a justified partial_cmp is allowed with a reason.
pub fn max_finite(xs: &[f64]) -> Option<f64> {
    xs.iter().copied().max_by(|a, b| {
        // lint:allow(ND-FLOAT): inputs are pre-filtered finite, NaN cannot reach this comparator
        a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal)
    })
}
