// Fixture: a par-section fn that reads shared state only through the
// frozen snapshot, mutates only its own tenant, and draws from its
// pre-forked sub-stream is clean. The unmarked fn below may touch shared
// state freely — PAR-SHARED is marker-driven.
// lint:par-section
fn tick_tenant_shard(wv: &WorldView<'_>, shard: &mut TenantShard<'_>) {
    let foreign = wv.total_in_flight[rid.0 as usize];
    shard.tenant.mark_view(rid);
    let roll = shard.rng.next_f64();
    shard.actions.push(Action::Submit { jid, rid, roll });
}

fn merge_barrier(world: &mut World, rid: ResourceId) {
    world.mark_view_all(rid);
    world.dec_total_in_flight(rid);
    let tie = world.rng_next();
}
