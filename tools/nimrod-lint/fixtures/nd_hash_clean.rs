// Fixture: ordered containers keep replay bit-exact — ND-HASH stays quiet.
use std::collections::BTreeMap;

pub fn occupancy_by_resource() -> BTreeMap<u32, u32> {
    let mut m = BTreeMap::new();
    m.insert(1, 2);
    m
}
