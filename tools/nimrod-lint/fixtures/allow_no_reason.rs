// Fixture: an allow marker without a reason is itself a violation and does
// not suppress the underlying diagnostic.
pub fn quiet_clock() -> u128 {
    // lint:allow(ND-CLOCK)
    let t0 = std::time::Instant::now();
    t0.elapsed().as_nanos()
}
