// Fixture: an allow with a reason silences ND-HASH.
pub fn intern_cache() -> usize {
    // lint:allow(ND-HASH): lookup-only interning cache, never iterated
    let m = std::collections::HashMap::<u32, u32>::new();
    m.len()
}
