// Fixture: PAR-SHARED fires on a WorkerPool scatter whose closure touches
// shared world state — no lint:par-section marker needed, the pool call
// itself places the closure in phase 2. Both the single-line form and a
// multi-line closure body are covered.
fn on_tick_batch(&mut self) {
    pool.scatter(&mut shards, |shard| shard.roll = self.rng.next_f64());
    pool.scatter(&mut shards, |shard| {
        let slot = self.total_in_flight[shard.rid.0 as usize];
        shard.actions.push(Action::Submit { jid, rid, slot });
    });
}
