// Fixture: DIRTY-PAIR fires when a fn marks views dirty but never re-keys
// the CandidateIndex.
impl World {
    fn poke(&mut self, rid: ResourceId) {
        self.tenants[0].mark_view(rid);
        self.report.pokes += 1;
    }
}
