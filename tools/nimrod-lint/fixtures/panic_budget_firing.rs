// Fixture: PANIC-BUDGET fires on unwrap in non-test library code.
pub fn head(xs: &[u32]) -> u32 {
    *xs.first().unwrap()
}
