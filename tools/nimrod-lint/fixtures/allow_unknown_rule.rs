// Fixture: an allow naming an unknown rule ID is flagged — typos must not
// silently disable enforcement.
pub fn noop() -> u32 {
    // lint:allow(ND-TYPO): misspelled rule ids must not pass silently
    0
}
