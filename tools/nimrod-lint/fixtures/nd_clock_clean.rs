// Fixture: virtual time flows in from simtime as a parameter — no OS clock.
pub fn tick(now_s: f64, step_s: f64) -> f64 {
    now_s + step_s
}
