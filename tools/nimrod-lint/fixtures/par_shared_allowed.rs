// Fixture: a reasoned allow on the offending line suppresses PAR-SHARED
// (e.g. a read-only audit of the shared occupancy table in a debug-only
// consistency check).
// lint:par-section
fn tick_tenant_shard(wv: &WorldView<'_>, shard: &mut TenantShard<'_>) {
    // lint:allow(PAR-SHARED): read-only debug audit against the live table; never written from here
    debug_assert_eq!(wv.total_in_flight[i], self.total_in_flight[i]);
    shard.tenant.mark_view(rid);
}
