// Fixture: documented wall-clock telemetry is allowed with a reason.
pub fn alloc_phase_ns() -> u128 {
    // lint:allow(ND-CLOCK): alloc_ns telemetry measures real elapsed time, never feeds sim state
    let t0 = std::time::Instant::now();
    t0.elapsed().as_nanos()
}
