// Fixture: a justified expect is allowed with a reason.
pub fn parse_port(s: &str) -> u16 {
    // lint:allow(PANIC-BUDGET): validated by the CLI arg parser before reaching here
    s.parse().expect("port validated upstream")
}
