// Fixture: marking and re-keying in the same body satisfies DIRTY-PAIR.
impl World {
    fn poke(&mut self, rid: ResourceId) {
        self.tenant.mark_view(rid);
        self.tenant.index.update(&self.tenant.views[rid.0 as usize]);
    }

    fn tick(&mut self) {
        self.refresh_dirty_views(0);
    }
}
