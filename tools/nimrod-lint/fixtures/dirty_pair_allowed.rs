// Fixture: a deferred re-key is allowed when the marker names where it
// happens.
impl World {
    // lint:allow(DIRTY-PAIR): deferred — refresh_dirty_views re-keys every queued view at tick start
    fn on_event(&mut self, rid: ResourceId) {
        self.mark_view_all(rid);
    }
}
