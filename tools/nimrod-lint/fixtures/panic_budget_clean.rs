// Fixture: unwrap inside a #[cfg(test)] module is test code — no budget.
pub fn head(xs: &[u32]) -> Option<u32> {
    xs.first().copied()
}

#[cfg(test)]
mod tests {
    #[test]
    fn head_of_singleton() {
        let x = super::head(&[3]).unwrap();
        assert_eq!(x, 3);
        let y = Some(4u32).expect("present");
        assert_eq!(y, 4);
    }
}
