// Fixture: PAR-SHARED fires when a par-section fn touches shared world
// state — here a cross-tenant dirty broadcast and a world-RNG draw.
// lint:par-section
fn tick_tenant_shard(wv: &WorldView<'_>, shard: &mut TenantShard<'_>) {
    shard.tenant.mark_view(rid);
    world.mark_view_all(rid);
    let roll = self.rng.next_f64();
    shard.actions.push(Action::Submit { jid, rid, roll });
}
