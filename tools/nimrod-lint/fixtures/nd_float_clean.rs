// Fixture: total_cmp gives a total order over floats — ND-FLOAT stays quiet.
pub fn rank(xs: &mut [f64]) {
    xs.sort_by(|a, b| a.total_cmp(b));
}
