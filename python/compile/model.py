"""L2: ionization-chamber calibration model (the Nimrod/G job payload).

The paper's Figure-3 experiment farms out an ionization-chamber calibration
code across design parameters. That code is proprietary, so we substitute a
physics-flavoured surrogate with the same I/O shape: per job a small set of
design parameters in, a scalar chamber response out (see DESIGN.md §2).

Per batch element the model computes, on an ``N x N`` chamber cross-section
with homogeneous Dirichlet walls:

  1. an ionization **source term** ``f`` — depth-wise Bragg-like deposition
     profile (peak position set by beam energy ``E``) times a Gaussian
     lateral beam profile, scaled by gas pressure ``P``;
  2. the **electrode potential** ``phi`` by a spectral Poisson solve
     (DST-I transform → divide by Laplacian eigenvalues → inverse
     transform), scaled by the electrode voltage ``V``;
  3. the **collection efficiency** ``eta = |grad phi| / (|grad phi| + k P)``
     — a saturation/recombination model: stronger fields collect more of the
     liberated charge, higher pressure recombines more;
  4. the **chamber response** ``sum(f * eta)`` and total **dose** ``sum(f)``.

The DST transforms (step 2) dominate the FLOPs and are the L1 Pallas kernel
(`kernels.dst2d`); everything else is plain jnp that XLA fuses around it.

Parameters (``params[B, 3]`` columns):
  * ``V``  electrode voltage, volts       (typical range 100 .. 1000)
  * ``P``  gas pressure, atm              (typical range 0.5 .. 2.0)
  * ``E``  beam energy, MeV               (typical range 1 .. 20)
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from compile.kernels import dst2d

# Chamber cross-section resolution. 64 keeps one (N, N) f32 block at 16 KiB —
# MXU-tile aligned (64 = 8 sublanes x 8) and trivially VMEM resident.
GRID_N = 64
# AOT batch size: the Rust job-wrapper executes jobs in batches of up to
# AOT_BATCH, padding the tail (see rust/src/runtime/).
AOT_BATCH = 16
# Number of per-job design parameters (V, P, E).
N_PARAMS = 3
# Recombination constant in the collection-efficiency model.
RECOMB_K = 8.0


def dst_matrix(n: int) -> np.ndarray:
    """DST-I basis matrix ``S[k, i] = sin(pi (k+1)(i+1) / (n+1))``.

    Symmetric, and ``S @ S = (n+1)/2 * I``, so the inverse transform is the
    same matrix scaled by ``2/(n+1)``.
    """
    idx = np.arange(1, n + 1)
    return np.sin(np.pi * np.outer(idx, idx) / (n + 1)).astype(np.float32)


def laplacian_eigenvalues(n: int) -> np.ndarray:
    """2-D eigenvalue grid ``lam_i + lam_j`` of the Dirichlet Laplacian.

    ``lam_k = 2 - 2 cos(pi (k+1) / (n+1))``, strictly positive, so the
    spectral solve never divides by zero.
    """
    k = np.arange(1, n + 1)
    lam = 2.0 - 2.0 * np.cos(np.pi * k / (n + 1))
    return (lam[:, None] + lam[None, :]).astype(np.float32)


def source_term(params: jnp.ndarray, n: int) -> jnp.ndarray:
    """Ionization source ``f[B, N, N]`` from (V, P, E) parameters.

    Depth axis 0 carries a Bragg-like profile peaking at the beam range
    (deeper for higher energy); axis 1 carries the lateral Gaussian beam
    profile. Pressure scales deposition density linearly.
    """
    p = params[:, 1][:, None]
    e = params[:, 2][:, None]
    x = jnp.linspace(0.0, 1.0, n, dtype=jnp.float32)[None, :]
    # Beam range grows sub-linearly with energy, clipped inside the chamber.
    rng = jnp.clip(0.12 * e**0.8, 0.05, 0.92)
    bragg = jnp.exp(-((x - rng) ** 2) / (2.0 * 0.05**2)) * (0.3 + x / rng)
    lateral = jnp.exp(-((x - 0.5) ** 2) / (2.0 * 0.12**2))
    return p[:, :, None] * bragg[:, :, None] * lateral[:, None, :]


def chamber_response(
    params: jnp.ndarray,
    s: jnp.ndarray,
    lam2d: jnp.ndarray,
    interpret: bool = True,
):
    """Batched chamber response.

    Args:
      params: ``[B, 3]`` design parameters (V, P, E) per job.
      s: ``[N, N]`` DST-I matrix (``dst_matrix(N)``).
      lam2d: ``[N, N]`` Laplacian eigenvalues (``laplacian_eigenvalues(N)``).
      interpret: run Pallas kernels in interpret mode (required on CPU).

    Returns:
      ``(response[B], dose[B])`` — collected charge and total deposited dose.
    """
    n = s.shape[0]
    v = params[:, 0]
    p = params[:, 1]

    f = source_term(params, n)

    # Spectral Poisson solve; the DST pairs are the L1 Pallas kernel.
    f_hat = dst2d.dst2d_batched(f, s, interpret=interpret)
    phi_hat = dst2d.spectral_solve_batched(f_hat, lam2d, interpret=interpret)
    inv_scale = (2.0 / (n + 1)) ** 2
    phi = dst2d.dst2d_batched(phi_hat, s, interpret=interpret) * inv_scale

    # Field magnitude from central differences, scaled by electrode voltage.
    gx = (jnp.roll(phi, -1, axis=1) - jnp.roll(phi, 1, axis=1)) * 0.5 * n
    gy = (jnp.roll(phi, -1, axis=2) - jnp.roll(phi, 1, axis=2)) * 0.5 * n
    emag = jnp.sqrt(gx**2 + gy**2 + 1e-12) * v[:, None, None]

    # Saturation/recombination collection efficiency.
    eta = emag / (emag + RECOMB_K * p[:, None, None])

    response = jnp.sum(f * eta, axis=(1, 2))
    dose = jnp.sum(f, axis=(1, 2))
    return response, dose


@functools.partial(jax.jit, static_argnames=("interpret",))
def chamber_response_jit(params, s, lam2d, interpret: bool = True):
    """Jitted wrapper used by tests and the AOT lowering."""
    return chamber_response(params, s, lam2d, interpret=interpret)


def chamber_response_ref(params: jnp.ndarray, n: int = GRID_N):
    """Pure-jnp oracle (no Pallas) used by pytest against the kernel path."""
    from compile.kernels import ref

    s = jnp.asarray(dst_matrix(n))
    lam2d = jnp.asarray(laplacian_eigenvalues(n))
    v = params[:, 0]
    p = params[:, 1]
    f = source_term(params, n)
    f_hat = ref.dst2d_batched_ref(f, s)
    phi_hat = ref.spectral_solve_batched_ref(f_hat, lam2d)
    phi = ref.dst2d_batched_ref(phi_hat, s) * (2.0 / (n + 1)) ** 2
    gx = (jnp.roll(phi, -1, axis=1) - jnp.roll(phi, 1, axis=1)) * 0.5 * n
    gy = (jnp.roll(phi, -1, axis=2) - jnp.roll(phi, 1, axis=2)) * 0.5 * n
    emag = jnp.sqrt(gx**2 + gy**2 + 1e-12) * v[:, None, None]
    eta = emag / (emag + RECOMB_K * p[:, None, None])
    return jnp.sum(f * eta, axis=(1, 2)), jnp.sum(f, axis=(1, 2))
