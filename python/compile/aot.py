"""AOT lowering: jax model → HLO *text* artifacts for the Rust runtime.

HLO text (NOT ``lowered.compile()``/``.serialize()``) is the interchange
format: jax ≥ 0.5 emits HloModuleProto with 64-bit instruction ids which the
``xla`` crate's bundled xla_extension 0.5.1 rejects (``proto.id() <=
INT_MAX``); the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/load_hlo and its README.

GOTCHA (discovered the hard way): the HLO text printer *elides large
constants* — a baked-in 64x64 DST matrix prints as ``constant({...})``,
which the XLA 0.5.1 text parser silently reads back as zeros. So the DST
matrix and eigenvalue grid are **arguments**, not closure constants: they
are exported as raw little-endian f32 files next to the HLO and fed as
inputs by the Rust runtime on every call.

Artifacts written (``make artifacts``):
  * ``chamber.hlo.txt``     — ``chamber_response`` at the AOT batch size.
  * ``chamber_b1.hlo.txt``  — batch-1 variant for latency-sensitive paths.
  * ``dst_matrix.f32``      — [N,N] DST-I basis, row-major f32.
  * ``laplacian.f32``       — [N,N] eigenvalue grid, row-major f32.
  * ``manifest.json``       — shapes/dtypes/entry metadata + golden probe
                              outputs the Rust test suite checks numerics
                              against.
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    """Convert a jax.jit(...).lower(...) result to XLA HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def entry_fn(params, s, lam2d):
    """The AOT entry point: everything is an argument (no big constants)."""
    return model.chamber_response(params, s, lam2d, interpret=True)


def lower_chamber(batch: int) -> str:
    """Lower chamber_response at a fixed batch size."""
    specs = (
        jax.ShapeDtypeStruct((batch, model.N_PARAMS), jnp.float32),
        jax.ShapeDtypeStruct((model.GRID_N, model.GRID_N), jnp.float32),
        jax.ShapeDtypeStruct((model.GRID_N, model.GRID_N), jnp.float32),
    )
    return to_hlo_text(jax.jit(entry_fn).lower(*specs))


def golden_probe():
    """Fixed probe batch + expected outputs (Rust numeric parity test)."""
    probe = np.array(
        [
            [150.0, 1.0, 10.0],
            [900.0, 1.0, 10.0],
            [400.0, 0.7, 4.0],
        ],
        dtype=np.float32,
    )
    s = jnp.asarray(model.dst_matrix(model.GRID_N))
    lam = jnp.asarray(model.laplacian_eigenvalues(model.GRID_N))
    response, dose = entry_fn(jnp.asarray(probe), s, lam)
    return probe, np.asarray(response), np.asarray(dose)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--out-dir", default="../artifacts", help="artifact output directory"
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    n = model.GRID_N
    s = model.dst_matrix(n)
    lam = model.laplacian_eigenvalues(n)
    for fname, arr in (("dst_matrix.f32", s), ("laplacian.f32", lam)):
        path = os.path.join(args.out_dir, fname)
        arr.astype("<f4").tofile(path)
        print(f"wrote {path} ({arr.size * 4} bytes)")

    artifacts = {}
    for name, batch in (
        ("chamber.hlo.txt", model.AOT_BATCH),
        ("chamber_b1.hlo.txt", 1),
    ):
        text = lower_chamber(batch)
        if "constant({...})" in text:
            raise RuntimeError(
                f"{name}: HLO printer elided a large constant — it would "
                "parse as zeros in the Rust loader"
            )
        path = os.path.join(args.out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        artifacts[name] = {
            "entry": "chamber_response",
            "batch": batch,
            "n_params": model.N_PARAMS,
            "grid_n": n,
            "inputs": [
                {"name": "params", "shape": [batch, model.N_PARAMS], "dtype": "f32"},
                {"name": "dst_matrix", "shape": [n, n], "dtype": "f32", "file": "dst_matrix.f32"},
                {"name": "laplacian", "shape": [n, n], "dtype": "f32", "file": "laplacian.f32"},
            ],
            "outputs": [
                {"name": "response", "shape": [batch], "dtype": "f32"},
                {"name": "dose", "shape": [batch], "dtype": "f32"},
            ],
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
        }
        print(f"wrote {path} ({len(text)} chars)")

    probe, response, dose = golden_probe()
    manifest_path = os.path.join(args.out_dir, "manifest.json")
    with open(manifest_path, "w") as f:
        json.dump(
            {
                "format": "hlo-text",
                "grid_n": n,
                "artifacts": artifacts,
                "golden": {
                    "params": probe.tolist(),
                    "response": response.tolist(),
                    "dose": dose.tolist(),
                },
            },
            f,
            indent=2,
        )
    print(f"wrote {manifest_path}")


if __name__ == "__main__":
    main()
