"""Pure-jnp oracles for the Pallas kernels.

These are the correctness references: every Pallas kernel in this package has
an equivalent here written with plain ``jax.numpy`` ops, and the pytest suite
asserts elementwise closeness across a hypothesis-driven sweep of shapes.
"""

import jax.numpy as jnp


def dst2d_batched_ref(x: jnp.ndarray, s: jnp.ndarray) -> jnp.ndarray:
    """Batched 2-D sine transform: ``S @ X_b @ S`` for every batch element.

    ``S`` is the symmetric DST-I matrix, so the same matrix is applied on both
    sides (S == S^T).

    Args:
      x: ``[B, N, N]`` batch of fields.
      s: ``[N, N]`` symmetric transform matrix.

    Returns:
      ``[B, N, N]`` transformed batch (f32).
    """
    return jnp.einsum(
        "ij,bjk,kl->bil",
        s.astype(jnp.float32),
        x.astype(jnp.float32),
        s.astype(jnp.float32),
    )


def spectral_solve_batched_ref(
    f_hat: jnp.ndarray, lam2d: jnp.ndarray
) -> jnp.ndarray:
    """Divide spectral coefficients by the 2-D Laplacian eigenvalues.

    Args:
      f_hat: ``[B, N, N]`` spectral source coefficients.
      lam2d: ``[N, N]`` eigenvalue grid ``lam_i + lam_j`` (strictly positive).

    Returns:
      ``[B, N, N]`` spectral potential coefficients (f32).
    """
    return f_hat.astype(jnp.float32) / lam2d.astype(jnp.float32)[None, :, :]
