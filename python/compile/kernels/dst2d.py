"""L1 Pallas kernel: batched 2-D sine transform (the model's hot spot).

The chamber model's dominant cost is the spectral Poisson solve, which is two
batched dense transform pairs ``S @ X_b @ S`` (DST-I is symmetric, so the same
matrix appears on both sides). Each transform is a chain of two ``N x N``
matmuls per batch element — exactly MXU-shaped work on TPU.

TPU mapping (see DESIGN.md §Hardware-Adaptation):
  * grid over the batch dimension; each program owns one ``[N, N]`` field;
  * BlockSpec pins ``x`` blocks to ``(1, N, N)`` and broadcasts ``s`` —
    with N=64/f32 a program touches 3·64·64·4 B ≈ 48 KiB of VMEM, far below
    the ~16 MiB budget, so the schedule is trivially resident;
  * the two ``jnp.dot``s inside the kernel hit the MXU systolic array with
    ``preferred_element_type=float32`` accumulation.

On this CPU-only image the kernel must run with ``interpret=True`` (real TPU
lowering emits a Mosaic custom-call the CPU PJRT plugin cannot execute); the
structure above is still what a TPU build would compile.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dst2d_kernel(x_ref, s_ref, o_ref):
    """One batch element: ``o = S @ x @ S`` (S symmetric)."""
    s = s_ref[...]
    x = x_ref[0, :, :]
    # Two back-to-back MXU matmuls with f32 accumulation.
    tmp = jnp.dot(s, x, preferred_element_type=jnp.float32)
    o_ref[0, :, :] = jnp.dot(tmp, s, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def dst2d_batched(x: jnp.ndarray, s: jnp.ndarray, interpret: bool = True):
    """Batched symmetric 2-D transform ``S @ X_b @ S`` as a Pallas call.

    Args:
      x: ``[B, N, N]`` batch of fields (any float dtype; accumulation in f32).
      s: ``[N, N]`` symmetric transform matrix.
      interpret: run the kernel in interpret mode (required on CPU).

    Returns:
      ``[B, N, N]`` transformed batch, dtype f32.
    """
    b, n, _ = x.shape
    return pl.pallas_call(
        _dst2d_kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, n, n), lambda i: (i, 0, 0)),
            pl.BlockSpec((n, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, n, n), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n, n), jnp.float32),
        interpret=interpret,
    )(x, s)


def _spectral_solve_kernel(fh_ref, lam_ref, o_ref):
    """One batch element: divide coefficients by Laplacian eigenvalues."""
    o_ref[0, :, :] = fh_ref[0, :, :] / lam_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def spectral_solve_batched(
    f_hat: jnp.ndarray, lam2d: jnp.ndarray, interpret: bool = True
):
    """Elementwise spectral Poisson solve ``f_hat / lam2d`` as a Pallas call.

    Kept as a separate tiny kernel (VPU-shaped, not MXU) so the transform and
    the solve can be fused differently by the scheduler on TPU.
    """
    b, n, _ = f_hat.shape
    return pl.pallas_call(
        _spectral_solve_kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, n, n), lambda i: (i, 0, 0)),
            pl.BlockSpec((n, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, n, n), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n, n), jnp.float32),
        interpret=interpret,
    )(f_hat, lam2d)
