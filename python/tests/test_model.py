"""L2 correctness: chamber model shapes, physics sanity, kernel-vs-ref path."""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


def params_batch(b, seed=0):
    r = np.random.RandomState(seed)
    v = r.uniform(100.0, 1000.0, size=b)
    p = r.uniform(0.5, 2.0, size=b)
    e = r.uniform(1.0, 20.0, size=b)
    return jnp.asarray(np.stack([v, p, e], axis=1), dtype=jnp.float32)


@pytest.fixture(scope="module")
def consts():
    s = jnp.asarray(model.dst_matrix(model.GRID_N))
    lam = jnp.asarray(model.laplacian_eigenvalues(model.GRID_N))
    return s, lam


def test_output_shapes(consts):
    s, lam = consts
    params = params_batch(model.AOT_BATCH)
    response, dose = model.chamber_response_jit(params, s, lam)
    assert response.shape == (model.AOT_BATCH,)
    assert dose.shape == (model.AOT_BATCH,)


@hypothesis.given(
    b=st.integers(min_value=1, max_value=8),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
@hypothesis.settings(max_examples=10, deadline=None)
def test_pallas_path_matches_pure_jnp_ref(b, seed):
    params = params_batch(b, seed)
    s = jnp.asarray(model.dst_matrix(model.GRID_N))
    lam = jnp.asarray(model.laplacian_eigenvalues(model.GRID_N))
    got_r, got_d = model.chamber_response_jit(params, s, lam)
    want_r, want_d = model.chamber_response_ref(params)
    np.testing.assert_allclose(got_r, want_r, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(got_d, want_d, rtol=1e-4, atol=1e-4)


def test_outputs_finite_and_physical(consts):
    s, lam = consts
    params = params_batch(32, seed=3)
    response, dose = model.chamber_response_jit(params, s, lam)
    assert np.isfinite(np.asarray(response)).all()
    assert np.isfinite(np.asarray(dose)).all()
    # Collected charge is positive and bounded by total deposited dose
    # (efficiency eta is in (0, 1)).
    assert (np.asarray(response) > 0).all()
    assert (np.asarray(response) <= np.asarray(dose) + 1e-5).all()


def test_voltage_increases_response(consts):
    """Higher electrode voltage collects more charge (saturation curve)."""
    s, lam = consts
    base = np.array([[200.0, 1.0, 10.0]], dtype=np.float32)
    hi = np.array([[800.0, 1.0, 10.0]], dtype=np.float32)
    r_lo, _ = model.chamber_response_jit(jnp.asarray(base), s, lam)
    r_hi, _ = model.chamber_response_jit(jnp.asarray(hi), s, lam)
    assert float(r_hi[0]) > float(r_lo[0])


def test_pressure_increases_dose(consts):
    """Higher gas pressure deposits more dose (linear density scaling)."""
    s, lam = consts
    lo = np.array([[400.0, 0.6, 10.0]], dtype=np.float32)
    hi = np.array([[400.0, 1.8, 10.0]], dtype=np.float32)
    _, d_lo = model.chamber_response_jit(jnp.asarray(lo), s, lam)
    _, d_hi = model.chamber_response_jit(jnp.asarray(hi), s, lam)
    assert float(d_hi[0]) > float(d_lo[0])


def test_energy_moves_bragg_peak():
    """Beam range (argmax of the depth profile) grows with beam energy."""
    n = model.GRID_N
    lo = model.source_term(jnp.asarray([[400.0, 1.0, 2.0]]), n)
    hi = model.source_term(jnp.asarray([[400.0, 1.0, 18.0]]), n)
    depth_lo = int(np.argmax(np.asarray(lo)[0].sum(axis=1)))
    depth_hi = int(np.argmax(np.asarray(hi)[0].sum(axis=1)))
    assert depth_hi > depth_lo
