"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps batch size, grid resolution, and input dtype; every case
asserts elementwise closeness against ``kernels/ref.py``. This is the core
correctness signal for the compute layer — the AOT artifact embeds exactly
these kernels.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import dst2d, ref

jax.config.update("jax_enable_x64", False)

HYP_SETTINGS = dict(max_examples=25, deadline=None)


def rand(shape, dtype, seed):
    r = np.random.RandomState(seed)
    return jnp.asarray(r.standard_normal(shape), dtype=dtype)


@hypothesis.given(
    b=st.integers(min_value=1, max_value=8),
    n=st.sampled_from([4, 8, 16, 32, 64]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
@hypothesis.settings(**HYP_SETTINGS)
def test_dst2d_matches_ref(b, n, dtype, seed):
    x = rand((b, n, n), dtype, seed)
    s = jnp.asarray(model.dst_matrix(n), dtype=dtype)
    got = dst2d.dst2d_batched(x, s, interpret=True)
    want = ref.dst2d_batched_ref(x, s)
    tol = 1e-4 if dtype == jnp.float32 else 2e-1
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol * n)


@hypothesis.given(
    b=st.integers(min_value=1, max_value=8),
    n=st.sampled_from([4, 8, 16, 32, 64]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
@hypothesis.settings(**HYP_SETTINGS)
def test_spectral_solve_matches_ref(b, n, seed):
    f_hat = rand((b, n, n), jnp.float32, seed)
    lam2d = jnp.asarray(model.laplacian_eigenvalues(n))
    got = dst2d.spectral_solve_batched(f_hat, lam2d, interpret=True)
    want = ref.spectral_solve_batched_ref(f_hat, lam2d)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n", [8, 16, 64])
def test_dst_matrix_is_self_inverse_up_to_scale(n):
    """DST-I property the spectral solve relies on: S @ S = (n+1)/2 * I."""
    s = model.dst_matrix(n)
    np.testing.assert_allclose(
        s @ s, np.eye(n) * (n + 1) / 2.0, rtol=1e-3, atol=1e-3
    )


@pytest.mark.parametrize("n", [8, 32, 64])
def test_laplacian_eigenvalues_positive(n):
    lam = model.laplacian_eigenvalues(n)
    assert lam.shape == (n, n)
    assert (lam > 0).all()


def test_poisson_roundtrip_solves_discrete_laplacian():
    """Full spectral pipeline solves -Delta phi = f for the 5-point stencil.

    Verifies the composed kernel path (transform → solve → inverse transform)
    against the algebraic definition, not just against ref.py.
    """
    n, b = 16, 3
    x = rand((b, n, n), jnp.float32, 7)
    s = jnp.asarray(model.dst_matrix(n))
    lam2d = jnp.asarray(model.laplacian_eigenvalues(n))
    f_hat = dst2d.dst2d_batched(x, s, interpret=True)
    phi_hat = dst2d.spectral_solve_batched(f_hat, lam2d, interpret=True)
    phi = np.asarray(
        dst2d.dst2d_batched(phi_hat, s, interpret=True) * (2.0 / (n + 1)) ** 2
    )
    # Apply the 5-point negative Laplacian with Dirichlet (zero) boundaries.
    padded = np.pad(phi, ((0, 0), (1, 1), (1, 1)))
    lap = (
        4 * padded[:, 1:-1, 1:-1]
        - padded[:, :-2, 1:-1]
        - padded[:, 2:, 1:-1]
        - padded[:, 1:-1, :-2]
        - padded[:, 1:-1, 2:]
    )
    np.testing.assert_allclose(lap, np.asarray(x), rtol=1e-3, atol=1e-3)
