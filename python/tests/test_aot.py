"""AOT artifact pipeline: lowering invariants the Rust loader depends on."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def hlo_b2():
    return aot.lower_chamber(2)


def test_hlo_text_has_entry_and_right_signature(hlo_b2):
    assert "ENTRY" in hlo_b2
    # params, dst matrix, laplacian as runtime arguments.
    assert "f32[2,3]" in hlo_b2
    assert hlo_b2.count("f32[64,64]") >= 2


def test_no_elided_large_constants(hlo_b2):
    """The HLO text printer elides big constants as `constant({...})`,
    which xla_extension 0.5.1 silently parses back as zeros — the bug that
    motivated passing the DST matrix as an argument. Guard it forever."""
    assert "constant({...})" not in hlo_b2


def test_lowering_deterministic():
    assert aot.lower_chamber(1) == aot.lower_chamber(1)


def test_golden_probe_matches_model():
    probe, response, dose = aot.golden_probe()
    s = jnp.asarray(model.dst_matrix(model.GRID_N))
    lam = jnp.asarray(model.laplacian_eigenvalues(model.GRID_N))
    want_r, want_d = model.chamber_response_jit(jnp.asarray(probe), s, lam)
    np.testing.assert_allclose(response, np.asarray(want_r), rtol=1e-5)
    np.testing.assert_allclose(dose, np.asarray(want_d), rtol=1e-5)
    assert np.isfinite(response).all() and (response > 0).all()


def test_entry_fn_jit_roundtrip_executes():
    """The exact entry signature the artifact freezes must execute in jax."""
    b = 4
    params = jnp.asarray(
        np.stack(
            [
                np.linspace(100, 1000, b),
                np.linspace(0.5, 2.0, b),
                np.linspace(1, 20, b),
            ],
            axis=1,
        ),
        dtype=jnp.float32,
    )
    s = jnp.asarray(model.dst_matrix(model.GRID_N))
    lam = jnp.asarray(model.laplacian_eigenvalues(model.GRID_N))
    response, dose = jax.jit(aot.entry_fn)(params, s, lam)
    assert response.shape == (b,)
    assert dose.shape == (b,)
    assert bool(jnp.all(response <= dose + 1e-4))
