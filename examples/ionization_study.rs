//! End-to-end driver (DESIGN.md §6): the ionization-chamber calibration
//! study executed **for real** — every job runs the AOT-compiled JAX+Pallas
//! chamber model through PJRT from Rust job-wrappers on worker threads,
//! with the Clustor TCP protocol serving live status to a monitor client.
//! Python is never on this path; `make artifacts` must have run first.
//!
//! This is the live-mode counterpart of the paper's Figure-3 experiment:
//! the same plan language, engine, economy ledger and scheduler drive real
//! compute, proving all three layers compose.
//!
//! ```bash
//! make artifacts && cargo run --release --example ionization_study
//! ```

use nimrod_g::broker::Broker;
use nimrod_g::client::{MonitorClient, StatusBoard, StatusServer};
use nimrod_g::protocol::Message;
use nimrod_g::workload::ionization_plan;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    // A reduced calibration sweep: 5 voltages x 3 pressures x 2 energies,
    // assembled through the broker and finished as a live experiment.
    let workdir = std::env::temp_dir().join("nimrod-ionization-study");
    let live = Broker::experiment()
        .plan(ionization_plan(5, 3, 2))
        .deadline_s(1800.0) // wall-clock seconds in live mode
        .policy("time")
        .seed(99)
        .live(6, &workdir)?;
    println!("ionization study: {} real jobs", live.job_count());

    // Engine-side status server (the paper's multi-site monitoring).
    let board = Arc::new(StatusBoard::default());
    let server = StatusServer::start(board.clone())?;
    println!("status server on {}", server.addr);

    // A monitor client polling from another thread while the run proceeds.
    let addr = server.addr;
    let monitor = std::thread::spawn(move || {
        let mut last = (0u32, 0u32);
        let Ok(mut client) = MonitorClient::connect(addr) else {
            return;
        };
        for _ in 0..600 {
            std::thread::sleep(std::time::Duration::from_millis(250));
            let Ok(Message::Status {
                jobs_total,
                jobs_completed,
                busy_workers,
                spent,
                ..
            }) = client.status()
            else {
                break;
            };
            if jobs_total > 0 && (jobs_completed, busy_workers) != last {
                println!(
                    "  [monitor] {jobs_completed}/{jobs_total} done, {busy_workers} busy, {spent:.1} G$ spent"
                );
                last = (jobs_completed, busy_workers);
            }
            if jobs_total > 0 && jobs_completed == jobs_total {
                break;
            }
        }
    });

    // Run on 6 PJRT workers.
    let outcome = live.with_board(board).run()?;
    monitor.join().ok();
    server.stop();

    println!("\n{}", outcome.report.summary());

    // The calibration curve the experiment exists to produce: response vs
    // voltage at fixed pressure/energy.
    println!("\ncalibration samples (response/dose per job):");
    let mut rows: Vec<_> = outcome.outputs.iter().collect();
    rows.sort_by_key(|(jid, _)| jid.0);
    for (jid, out) in rows.iter().take(10) {
        println!("  {jid}: response={:.4} dose={:.3}", out.response, out.dose);
    }
    println!("  ... {} jobs total", rows.len());
    println!(
        "\nstaged result files in {}",
        workdir.join("rootstore").display()
    );
    Ok(())
}
