//! GRACE market demo (paper §7): the broker negotiates resources for an
//! experiment *before it starts* — tender rounds, per-owner bid strategies,
//! deadline-aware bid selection, and the renegotiation loop of §3: "the
//! user knows before the experiment is started whether the system can
//! deliver the results and what the cost will be".
//!
//! For the *live* market — auctions running inside a multi-tenant world
//! with awards feeding the scheduler — run
//! `cargo run --release --bin nimrod -- run --scenario grace-auction`.
//!
//! ```bash
//! cargo run --release --example economy_market
//! ```

use nimrod_g::economy::grace::{BidServer, BidStrategy, Broker, Tender};
use nimrod_g::economy::price::PriceModel;
use nimrod_g::grid::testbed::local_hour;
use nimrod_g::grid::Testbed;
use nimrod_g::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let tb = Testbed::gusto(42, 1.0);
    let mut rng = Rng::new(7);

    // Each resource owner runs a bid-server with its own temperament and a
    // load snapshot; quotes are time-of-day priced in the owner's timezone.
    let utc_hour = 22.0;
    let servers: Vec<BidServer> = tb
        .resources
        .iter()
        .map(|spec| {
            let lh = local_hour(utc_hour, tb.site(spec.site).tz_offset_hours);
            let strategy = match rng.below(3) {
                0 => BidStrategy::Aggressive,
                1 => BidStrategy::ListPrice,
                _ => BidStrategy::Premium,
            };
            let utilization = rng.uniform(0.0, 0.9);
            BidServer {
                resource: spec.id,
                speed: spec.speed,
                free_slots: ((1.0 - utilization) * spec.cpus as f64).floor()
                    as u32,
                posted_rate: spec.price.rate_at(lh, "rajkumar"),
                utilization,
                strategy,
            }
        })
        .collect();
    println!(
        "market: {} bid-servers across {} sites (UTC {:02.0}:00)",
        servers.len(),
        tb.sites.len(),
        utc_hour
    );

    let broker = Broker::default();
    println!("\n-- scenario 1: relaxed deadline, low reservation rate --");
    run_tender(&broker, &tb, &servers, 165, 20.0, 0.4);

    println!("\n-- scenario 2: tight deadline, same reservation rate --");
    run_tender(&broker, &tb, &servers, 165, 6.0, 0.4);

    println!("\n-- scenario 3: impossible ask (escalation exhausts) --");
    let broke = Broker {
        max_rounds: 3,
        escalation: 1.05,
    };
    run_tender(&broke, &tb, &servers, 5000, 1.0, 0.01);

    // Show the peak/off-peak effect the §3 parameter list calls out
    // (pick an owner that actually uses time-of-day pricing).
    println!("\n-- time-of-day pricing on one owner --");
    let spec = tb
        .resources
        .iter()
        .find(|r| r.price.time_of_day)
        .unwrap_or(&tb.resources[0]);
    demo_time_of_day(&spec.price);
    Ok(())
}

fn run_tender(
    broker: &Broker,
    tb: &Testbed,
    servers: &[BidServer],
    jobs: u32,
    hours: f64,
    rate: f64,
) {
    let tender = Tender {
        user: "rajkumar".into(),
        jobs,
        job_work_ref_h: 2.0,
        time_to_deadline_s: hours * 3600.0,
        max_rate: rate,
        hard_rate_cap: None,
    };
    println!(
        "tender: {jobs} jobs x {}h work, deadline {hours} h, reservation {rate} G$/cpu-s",
        tender.job_work_ref_h
    );
    let outcome = broker.negotiate(tender, servers);
    if outcome.is_deal() {
        println!(
            "  deal after {} round(s) at max rate {:.3}: {} resources, est. {:.0} G$",
            outcome.rounds,
            outcome.final_max_rate,
            outcome.selected.len(),
            outcome.est_total_cost
        );
        for bid in outcome.selected.iter().take(5) {
            println!(
                "    {} @ {:.3} G$/cpu-s x{} (speed {:.2})",
                tb.spec(bid.resource).name,
                bid.rate,
                bid.capacity,
                bid.speed
            );
        }
        if outcome.selected.len() > 5 {
            println!("    ... {} more", outcome.selected.len() - 5);
        }
    } else {
        // The failed loop reports its best offer, not a bare None: the
        // caller can tell the user what the market refused (paper §3's
        // "renegotiate deadline and/or cost").
        let rejected = outcome.best_rejected.expect("failure carries tender");
        println!(
            "  NO DEAL after {} round(s) — even {:.3} G$/cpu-s for {} jobs in {hours} h was refused; renegotiate deadline or price (paper §3)",
            outcome.rounds, rejected.max_rate, rejected.jobs
        );
    }
}

fn demo_time_of_day(price: &PriceModel) {
    for hour in [3.0, 9.0, 13.0, 19.0] {
        println!(
            "  local {:>2.0}:00 -> {:.3} G$/cpu-s{}",
            hour,
            price.rate_at(hour, "rajkumar"),
            if price.is_peak(hour) { "  (peak)" } else { "" }
        );
    }
}
