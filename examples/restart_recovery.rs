//! Restart/recovery demo (paper §2): "the parametric engine ... ensures
//! that the state is recorded in persistent storage. This allows the
//! experiment to be restarted if the node running Nimrod goes down."
//!
//! The experiment runs for a few virtual hours with a journal attached,
//! then the engine "crashes" (we drop the simulation mid-flight). A fresh
//! engine recovers the job table from the journal — completed jobs stay
//! completed, in-flight jobs roll back to Ready — and finishes the study.
//!
//! ```bash
//! cargo run --release --example restart_recovery
//! ```

use nimrod_g::config::ExperimentConfig;
use nimrod_g::engine::journal::{recover, Journal};
use nimrod_g::grid::Testbed;
use nimrod_g::sim::GridSimulation;
use nimrod_g::types::HOUR;
use nimrod_g::workload::{ionization_jobs, ionization_plan};

fn main() -> anyhow::Result<()> {
    let dir = std::env::temp_dir().join("nimrod-restart-demo");
    std::fs::create_dir_all(&dir)?;
    let journal_path = dir.join("experiment.journal");

    let cfg = ExperimentConfig {
        deadline: 15.0 * HOUR,
        policy: "cost".to_string(),
        seed: 4242,
        ..Default::default()
    };
    let plan_src = ionization_plan(11, 5, 3);
    let specs = ionization_jobs(cfg.seed);
    println!("experiment: {} jobs, journaling to {}", specs.len(), journal_path.display());

    // Phase 1: run ~5 virtual hours, then crash.
    let tb = Testbed::gusto(cfg.seed ^ 0x6057, 1.0);
    let mut sim = GridSimulation::new(tb.clone(), specs, cfg.clone());
    let journal = Journal::create(&journal_path, &plan_src, cfg.seed, &sim.exp)?;
    sim = sim.with_journal(journal);
    sim.run_until(5.0 * HOUR);
    println!(
        "crash at t=5h: {} done, {} remaining (journal flushed per record)",
        sim.exp.completed(),
        sim.exp.remaining()
    );
    let done_before = sim.exp.completed();
    drop(sim); // the engine node dies

    // Phase 2: recover from the journal and finish.
    let rec = recover(&journal_path)?;
    println!(
        "recovered: {} done survive the crash, {} jobs to go",
        rec.experiment.completed(),
        rec.experiment.remaining()
    );
    assert_eq!(rec.experiment.completed(), done_before);

    let journal = Journal::append_to(&journal_path)?;
    let sim2 = GridSimulation::new(tb, Vec::new(), cfg)
        .with_experiment(rec.experiment)
        .with_journal(journal);
    let report = sim2.run();
    println!("\nafter restart: {}", report.summary());
    assert_eq!(
        report.jobs_completed + report.jobs_failed,
        report.jobs_total,
        "every job must reach a terminal state across the restart"
    );
    println!("journal bytes: {}", std::fs::metadata(&journal_path)?.len());
    std::fs::remove_dir_all(&dir).ok();
    Ok(())
}
