//! Restart/recovery demo (paper §2): "the parametric engine ... ensures
//! that the state is recorded in persistent storage. This allows the
//! experiment to be restarted if the node running Nimrod goes down."
//!
//! The experiment runs for a few virtual hours with a journal attached,
//! then the engine "crashes" (we drop the simulation mid-flight). A fresh
//! engine recovers the job table from the journal — completed jobs stay
//! completed, in-flight jobs roll back to Ready — and finishes the study.
//!
//! ```bash
//! cargo run --release --example restart_recovery
//! ```

use nimrod_g::broker::Broker;
use nimrod_g::engine::journal::{recover, Journal};
use nimrod_g::types::HOUR;
use nimrod_g::workload::ionization_plan;

const SEED: u64 = 4242;

fn main() -> anyhow::Result<()> {
    let dir = std::env::temp_dir().join("nimrod-restart-demo");
    std::fs::create_dir_all(&dir)?;
    let journal_path = dir.join("experiment.journal");
    let plan_src = ionization_plan(11, 5, 3);

    // Phase 1: run ~5 virtual hours with a journal attached, then crash.
    let mut sim = Broker::experiment()
        .deadline_h(15.0)
        .policy("cost")
        .seed(SEED)
        .simulate()?;
    println!(
        "experiment: {} jobs, journaling to {}",
        sim.exp().jobs.len(),
        journal_path.display()
    );
    let journal = Journal::create(&journal_path, &plan_src, SEED, sim.exp())?;
    sim = sim.with_journal(journal);
    sim.run_until(5.0 * HOUR);
    println!(
        "crash at t=5h: {} done, {} remaining (journal flushed per record)",
        sim.exp().completed(),
        sim.exp().remaining()
    );
    let done_before = sim.exp().completed();
    drop(sim); // the engine node dies

    // Phase 2: recover from the journal and finish. The same seed rebuilds
    // the identical testbed; the recovered job table replaces the specs.
    let rec = recover(&journal_path)?;
    println!(
        "recovered: {} done survive the crash, {} jobs to go",
        rec.experiment.completed(),
        rec.experiment.remaining()
    );
    assert_eq!(rec.experiment.completed(), done_before);

    let journal = Journal::append_to(&journal_path)?;
    let sim2 = Broker::experiment()
        .deadline_h(15.0)
        .policy("cost")
        .seed(SEED)
        .resume(rec.experiment)
        .simulate()?
        .with_journal(journal);
    let report = sim2.run();
    println!("\nafter restart: {}", report.summary());
    assert_eq!(
        report.jobs_completed + report.jobs_failed,
        report.jobs_total,
        "every job must reach a terminal state across the restart"
    );
    println!("journal bytes: {}", std::fs::metadata(&journal_path)?.len());
    std::fs::remove_dir_all(&dir).ok();
    Ok(())
}
