//! Quickstart: parse a plan, expand it, and run it on the simulated GUSTO
//! testbed with the cost-optimizing deadline/budget scheduler.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use nimrod_g::config::ExperimentConfig;
use nimrod_g::grid::Testbed;
use nimrod_g::plan::{expand, Plan};
use nimrod_g::sim::GridSimulation;
use nimrod_g::types::HOUR;

const PLAN: &str = r#"
# A small parametric study: 3 voltages x 2 pressures x 2 energies = 12 jobs.
parameter voltage label "electrode voltage (V)" float range from 200 to 800 step 300
parameter pressure label "gas pressure (atm)" float select anyof 0.8 1.5
parameter energy label "beam energy (MeV)" float select anyof 5.0 15.0
constant chamber text "icc-mk2"

task main
    copy chamber.cfg node:chamber.cfg
    execute ./icc_sim -v $voltage -p $pressure -e $energy -c $chamber -o results.dat
    copy node:results.dat results.$jobname.dat
endtask
"#;

fn main() -> anyhow::Result<()> {
    // 1. Parse the declarative plan and expand the parameter space.
    let plan = Plan::parse(PLAN)?;
    println!(
        "plan: {} parameters, {} constants, {} task ops -> {} jobs",
        plan.parameters.len(),
        plan.constants.len(),
        plan.task.len(),
        plan.job_count()
    );
    let cfg = ExperimentConfig {
        deadline: 12.0 * HOUR,
        budget: Some(200_000.0),
        policy: "cost".to_string(),
        seed: 2026,
        ..Default::default()
    };
    let jobs = expand(&plan, cfg.seed)?;
    for job in jobs.iter().take(3) {
        println!("  {}: {:?}", job.id, job.bindings);
    }
    println!("  ...");

    // 2. Build a small grid (half-scale GUSTO) and run the experiment.
    let tb = Testbed::gusto(11, 0.5);
    println!(
        "\ntestbed: {} machines / {} cpus across {} sites",
        tb.resources.len(),
        tb.total_cpus(),
        tb.sites.len()
    );
    let report = GridSimulation::new(tb, jobs, cfg).run();

    // 3. Report.
    println!("\n{}", report.summary());
    println!("\nper-resource usage:\n{}", report.per_resource_csv());
    Ok(())
}
