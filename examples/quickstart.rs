//! Quickstart: compose an experiment through the broker — plan, envelope,
//! policy, testbed, seed — and run it on the simulated GUSTO testbed with
//! the cost-optimizing deadline/budget scheduler.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use nimrod_g::broker::Broker;
use nimrod_g::plan::Plan;

const PLAN: &str = r#"
# A small parametric study: 3 voltages x 2 pressures x 2 energies = 12 jobs.
parameter voltage label "electrode voltage (V)" float range from 200 to 800 step 300
parameter pressure label "gas pressure (atm)" float select anyof 0.8 1.5
parameter energy label "beam energy (MeV)" float select anyof 5.0 15.0
constant chamber text "icc-mk2"

task main
    copy chamber.cfg node:chamber.cfg
    execute ./icc_sim -v $voltage -p $pressure -e $energy -c $chamber -o results.dat
    copy node:results.dat results.$jobname.dat
endtask
"#;

fn main() -> anyhow::Result<()> {
    // A peek at what the declarative plan expands to.
    let plan = Plan::parse(PLAN)?;
    println!(
        "plan: {} parameters, {} constants, {} task ops -> {} jobs",
        plan.parameters.len(),
        plan.constants.len(),
        plan.task.len(),
        plan.job_count()
    );

    // The broker is the single entry point: one fluent chain assembles the
    // experiment (plan + envelope + policy spec + testbed + seed) and
    // `.simulate()` hands back the virtual-time driver.
    let sim = Broker::experiment()
        .plan(PLAN)
        .deadline_h(12.0)
        .budget(200_000.0)
        .policy("cost?safety=0.9") // parameterized policy spec
        .testbed_scale(0.5) // half-scale GUSTO: ~35 machines
        .seed(2026)
        .simulate()?;
    println!(
        "\ntestbed: {} machines / {} cpus across {} sites",
        sim.tb().resources.len(),
        sim.tb().total_cpus(),
        sim.tb().sites.len()
    );
    let report = sim.run();

    println!("\n{}", report.summary());
    println!("\nper-resource usage:\n{}", report.per_resource_csv());

    // Named presets compose testbed + dynamics + competition in one call —
    // still seedable, still overridable.
    let crowd = Broker::scenario("flash-crowd")?.seed(2026).run()?;
    println!("flash-crowd scenario: {}", crowd.summary());
    Ok(())
}
